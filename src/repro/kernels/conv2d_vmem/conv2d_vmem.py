"""Pallas TPU kernel: weights-resident direct convolution (BraggNN path).

The paper's headline resource result is that at (5,4)/(5,3) precision the
*entire* BraggNN weight set fits in registers/LUTs — no BRAM.  The TPU
analogue: all conv weights live in VMEM for the kernel's lifetime (~59 KB
at s=1), the batch streams through in blocks, and each (kh, kw) tap is one
MXU contraction over input channels.  Valid padding, stride 1, NCHW —
matching the loop-nest semantics of ``repro.core.frontend.conv2d``.

Grid: (B / bb,).  Per step: x block (bb, Cin, H, W) + full weights ->
out block (bb, Cout, Ho, Wo).  Optional fused ReLU and (wE,wF) weight
quantisation (performed in VMEM, the FloPoCo discipline).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.smallfloat_matmul.smallfloat_matmul import _quantize_block


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, kh, kw, fmt, fuse_relu):
    x = x_ref[...].astype(jnp.float32)            # (bb, Cin, H, W)
    w = w_ref[...].astype(jnp.float32)            # (Cout, Cin, kh, kw)
    if fmt is not None:
        x = _quantize_block(x, *fmt)
        w = _quantize_block(w, *fmt)
    bb, cin, h, wdim = x.shape
    cout = w.shape[0]
    ho, wo = h - kh + 1, wdim - kw + 1
    acc = jnp.zeros((bb, cout, ho, wo), jnp.float32)
    for i in range(kh):
        for j in range(kw):
            patch = x[:, :, i:i + ho, j:j + wo]   # (bb, Cin, Ho, Wo)
            tap = w[:, :, i, j]                   # (Cout, Cin)
            acc = acc + jax.lax.dot_general(
                tap, patch.reshape(bb, cin, ho * wo),
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            ).transpose(1, 0, 2).reshape(bb, cout, ho, wo)
    if b_ref is not None:
        acc = acc + b_ref[...].astype(jnp.float32)[None, :, None, None]
    if fuse_relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def _conv_kernel_nobias(x_ref, w_ref, o_ref, **kw):
    _conv_kernel(x_ref, w_ref, None, o_ref, **kw)


@functools.partial(jax.jit, static_argnames=(
    "fmt", "fuse_relu", "bb", "interpret"))
def conv2d_vmem(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
                *, fmt: Optional[tuple[int, int]] = None,
                fuse_relu: bool = False, bb: int = 8,
                interpret: bool = True) -> jax.Array:
    """x: (B, Cin, H, W), w: (Cout, Cin, kh, kw), b: (Cout,) -> fp32."""
    bsz, cin, h, wdim = x.shape
    cout, cin2, kh, kw = w.shape
    assert cin == cin2
    bb = min(bb, bsz)
    assert bsz % bb == 0, (bsz, bb)
    ho, wo = h - kh + 1, wdim - kw + 1
    grid = (bsz // bb,)

    in_specs = [
        pl.BlockSpec((bb, cin, h, wdim), lambda i: (i, 0, 0, 0)),
        pl.BlockSpec((cout, cin, kh, kw), lambda i: (0, 0, 0, 0)),
    ]
    args = [x, w]
    kernel = _conv_kernel_nobias
    if b is not None:
        in_specs.append(pl.BlockSpec((cout,), lambda i: (0,)))
        args.append(b)
        kernel = _conv_kernel
    return pl.pallas_call(
        functools.partial(kernel, kh=kh, kw=kw, fmt=fmt,
                          fuse_relu=fuse_relu),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, cout, ho, wo), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, cout, ho, wo), jnp.float32),
        interpret=interpret,
    )(*args)
