"""Pallas TPU kernels (+ jnp oracles) for the perf-critical compute.

Each kernel directory holds:
  <name>.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target;
              validated via interpret=True on CPU)
  ops.py    — the jit'd public wrapper (oracle fallback off-TPU)
  ref.py    — the pure-jnp oracle

smallfloat_matmul — reduced-precision MAC array (paper §4.2)
conv2d_vmem       — weights-resident BraggNN conv (paper's no-BRAM result)
flash_attention   — blockwise attention (32k prefill path)
fused_softmax     — fused softmax incl. Taylor-exp mode (paper §3/§4.1)

``registry.py`` catalogues the four as pattern-matched fast paths
(``KERNELS``: nn-graph node -> kernel entry) plus the scalar-DFG opcode
table (``OPCODE_KERNELS``) — the tables the Pallas emission backend
(``repro.core.emit_pallas``) lowers through.
"""
