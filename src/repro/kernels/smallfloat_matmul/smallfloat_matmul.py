"""Pallas TPU kernel: blocked matmul with on-the-fly FloPoCo (wE,wF)
quantisation of both operands, fp32 MXU accumulation, optional fused bias
and ReLU.

This is the TPU rendering of the paper's reduced-precision MAC array
(§4.2): operands are rounded to the (wE,wF) lattice *in VMEM* immediately
before hitting the MXU, exactly as FloPoCo cores consume reduced-precision
inputs, and the accumulator stays wide (fp32) like the DSP48 accumulator.

Grid: (M/bm, N/bn, K/bk), K innermost; the output block is revisited across
the K dimension and accumulated in place (init at k==0), the canonical TPU
matmul schedule.  Block shapes default to MXU-aligned (128, 128, 128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_block(x, exp_bits, man_bits):
    """RNE quantisation to (wE,wF) with FTZ + saturation (fp32 in/out).

    ``exp_bits=None`` means full fp32 — the identity — so one kernel serves
    both the reduced-precision MAC array and the plain fp32 fast path.
    """
    if exp_bits is None:
        return x
    bias = (1 << (exp_bits - 1)) - 1
    emax = bias
    emin = 1 - bias
    max_value = (2.0 - 2.0 ** (-man_bits)) * 2.0 ** emax
    min_normal = 2.0 ** emin
    sign = jnp.sign(x)
    v = jnp.abs(x)
    f, e = jnp.frexp(v)
    m = f * 2.0
    e = e - 1
    scale = float(1 << man_bits)
    q = jnp.round((m - 1.0) * scale)
    carry = q >= scale
    m_q = jnp.where(carry, 1.0, 1.0 + q / scale)
    e_q = jnp.where(carry, e + 1, e)
    out = sign * m_q * jnp.exp2(e_q.astype(jnp.float32))
    out = jnp.where(v < min_normal * 0.5, 0.0, out)
    out = jnp.where((v >= min_normal * 0.5) & (v < min_normal),
                    sign * min_normal, out)
    out = jnp.where(v > max_value, sign * max_value, out)
    out = jnp.where(v == 0.0, x, out)
    return out


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, exp_bits, man_bits,
                   fuse_relu, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = _quantize_block(x_ref[...].astype(jnp.float32), exp_bits, man_bits)
    w = _quantize_block(w_ref[...].astype(jnp.float32), exp_bits, man_bits)
    o_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _finish():
        acc = o_ref[...]
        if b_ref is not None:
            acc = acc + b_ref[...].astype(jnp.float32)
        if fuse_relu:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=(
    "exp_bits", "man_bits", "fuse_relu", "bm", "bn", "bk", "interpret"))
def smallfloat_matmul(x: jax.Array, w: jax.Array, b=None, *,
                      exp_bits: int = 5, man_bits: int = 4,
                      fuse_relu: bool = False, bm: int = 128, bn: int = 128,
                      bk: int = 128, interpret: bool = True) -> jax.Array:
    """x: (M, K), w: (K, N), b: (N,) or None  ->  (M, N) fp32."""
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, kdim)
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, (
        "dims must tile evenly", (m, n, kdim), (bm, bn, bk))
    n_k = kdim // bk
    grid = (m // bm, n // bn, n_k)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    args = [x, w]
    if b is not None:
        # bias kept 2-D: TPU VMEM tiles are (sublane, lane)-shaped
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        args.append(b.reshape(1, n))

    kernel = functools.partial(
        _matmul_kernel if b is not None else _matmul_kernel_nobias,
        exp_bits=exp_bits, man_bits=man_bits, fuse_relu=fuse_relu, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(*args)


def _matmul_kernel_nobias(x_ref, w_ref, o_ref, **kw):
    _matmul_kernel(x_ref, w_ref, None, o_ref, **kw)
