"""Pure-jnp oracle for smallfloat_matmul."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.precision import FloatFormat, quantize


def smallfloat_matmul_ref(x: jax.Array, w: jax.Array, b=None, *,
                          exp_bits: int = 5, man_bits: int = 4,
                          fuse_relu: bool = False) -> jax.Array:
    xq, wq = x.astype(jnp.float32), w.astype(jnp.float32)
    if exp_bits is not None:      # None = plain fp32 (no quantisation)
        fmt = FloatFormat(exp_bits, man_bits)
        xq = quantize(xq, fmt)
        wq = quantize(wq, fmt)
    out = xq @ wq
    if b is not None:
        out = out + b.astype(jnp.float32)
    if fuse_relu:
        out = jnp.maximum(out, 0.0)
    return out
