"""Public jit'd wrapper for the smallfloat matmul kernel.

``use_pallas=False`` (the CPU-container default) routes to the oracle;
``use_pallas=True`` routes to the kernel (interpret mode off-TPU).
"""

from __future__ import annotations

import jax

from repro.kernels.smallfloat_matmul.ref import smallfloat_matmul_ref
from repro.kernels.smallfloat_matmul.smallfloat_matmul import smallfloat_matmul


def matmul(x: jax.Array, w: jax.Array, b=None, *, exp_bits=5,
           man_bits=4, fuse_relu: bool = False,
           use_pallas: bool = False, interpret: bool = True) -> jax.Array:
    """``exp_bits=None`` skips operand quantisation (plain fp32 matmul)."""
    if use_pallas:
        return smallfloat_matmul(x, w, b, exp_bits=exp_bits,
                                 man_bits=man_bits, fuse_relu=fuse_relu,
                                 interpret=interpret)
    return smallfloat_matmul_ref(x, w, b, exp_bits=exp_bits,
                                 man_bits=man_bits, fuse_relu=fuse_relu)
