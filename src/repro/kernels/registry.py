"""Kernel registry — the catalogue ``emit_pallas`` lowers through.

Two tables:

* :data:`KERNELS` — the four hand-written Pallas exemplars, registered as
  pattern-matched fast paths for the loop nests the nn bridge emits
  (``Conv2d`` -> conv2d_vmem, ``Linear`` -> smallfloat_matmul,
  ``Softmax`` / the NLB attention softmax -> fused_softmax, the whole NLB
  attention core -> flash_attention).  Each entry carries the unified
  wrapper (oracle off-TPU, ``use_pallas=True`` routes to the
  ``pl.pallas_call`` kernel, interpret mode off-accelerator), the raw
  kernel, and the pure-jnp oracle, so callers pick the execution mode
  without knowing the module layout.

* :data:`OPCODE_KERNELS` — the scalar-DFG opcode -> vectorised jnp compute
  table used by the generic tier: contiguous runs of levelised
  (level, opcode) groups whose opcodes all appear here are fused into one
  compiled segment; a group whose opcode is missing falls back to the
  plain tensor path (and is recorded in the ``PallasPlan``).

Registration is open: ``register()`` accepts new entries (e.g. a
transformer-block kernel) without touching the emitter.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional


@dataclasses.dataclass(frozen=True)
class KernelEntry:
    """One registered kernel: unified wrapper + raw kernel + oracle."""

    name: str
    fn: Callable          #: unified wrapper (``use_pallas=``/``interpret=``)
    kernel: Callable      #: the raw ``pl.pallas_call`` implementation
    oracle: Callable      #: the pure-jnp reference
    accelerates: tuple[str, ...]   #: nn-graph node/nest patterns served
    description: str = ""


KERNELS: dict[str, KernelEntry] = {}


def register(entry: KernelEntry) -> KernelEntry:
    if entry.name in KERNELS:
        raise ValueError(f"kernel {entry.name!r} already registered")
    KERNELS[entry.name] = entry
    return entry


def get(name: str) -> KernelEntry:
    try:
        return KERNELS[name]
    except KeyError:
        raise KeyError(f"no kernel {name!r}; registered: "
                       f"{sorted(KERNELS)}") from None


def names() -> list[str]:
    return sorted(KERNELS)


def for_pattern(pattern: str) -> Optional[KernelEntry]:
    """The registered fast path for an nn-graph pattern name, if any."""
    for entry in KERNELS.values():
        if pattern in entry.accelerates:
            return entry
    return None


def _register_exemplars() -> None:
    from repro.kernels.conv2d_vmem import conv2d_vmem as _conv_mod
    from repro.kernels.conv2d_vmem import ops as _conv_ops
    from repro.kernels.conv2d_vmem import ref as _conv_ref
    from repro.kernels.flash_attention import flash_attention as _fa_mod
    from repro.kernels.flash_attention import ops as _fa_ops
    from repro.kernels.flash_attention import ref as _fa_ref
    from repro.kernels.fused_softmax import fused_softmax as _sm_mod
    from repro.kernels.fused_softmax import ops as _sm_ops
    from repro.kernels.fused_softmax import ref as _sm_ref
    from repro.kernels.smallfloat_matmul import ops as _mm_ops
    from repro.kernels.smallfloat_matmul import ref as _mm_ref
    from repro.kernels.smallfloat_matmul import \
        smallfloat_matmul as _mm_mod

    register(KernelEntry(
        name="conv2d_vmem",
        fn=_conv_ops.conv2d,
        kernel=_conv_mod.conv2d_vmem,
        oracle=_conv_ref.conv2d_ref,
        accelerates=("Conv2d", "nlb.conv1x1"),
        description="weights-resident valid conv, optional fused ReLU + "
                    "(wE,wF) operand quantisation"))
    register(KernelEntry(
        name="smallfloat_matmul",
        fn=_mm_ops.matmul,
        kernel=_mm_mod.smallfloat_matmul,
        oracle=_mm_ref.smallfloat_matmul_ref,
        accelerates=("Linear", "MLP", "Attention.proj"),
        description="blocked matmul, fp32 accumulate, optional (wE,wF) "
                    "operand quantisation + fused bias/ReLU"))
    register(KernelEntry(
        name="fused_softmax",
        fn=_sm_ops.softmax,
        kernel=_sm_mod.fused_softmax,
        oracle=_sm_ref.fused_softmax_ref,
        accelerates=("Softmax", "nlb.soft", "Attention.soft"),
        description="row softmax in one VMEM residency, incl. the paper's "
                    "Taylor-exp mode (matches the DFG functional model)"))
    register(KernelEntry(
        name="flash_attention",
        fn=_fa_ops.attention,
        kernel=_fa_mod.flash_attention,
        oracle=_fa_ref.flash_attention_ref,
        accelerates=("NonLocalBlock.attention", "Attention"),
        description="blockwise attention; NLB throughput mode "
                    "(true-exp softmax — not the Taylor functional model)"))


_register_exemplars()


# ---------------------------------------------------------------------------
# Generic tier: scalar-DFG opcode -> vectorised jnp compute
# ---------------------------------------------------------------------------

def _opcode_table():
    import jax.numpy as jnp

    return {
        # opcode -> (arity, compute over gathered operand vectors)
        "mulf": (2, lambda a: a[0] * a[1]),
        "addf": (2, lambda a: a[0] + a[1]),
        "subf": (2, lambda a: a[0] - a[1]),
        "divf": (2, lambda a: a[0] / a[1]),
        "sqrtf": (1, lambda a: jnp.sqrt(a[0])),
        "maxf": (2, lambda a: jnp.maximum(a[0], a[1])),
        "minf": (2, lambda a: jnp.minimum(a[0], a[1])),
        "negf": (1, lambda a: -a[0]),
        "relu": (1, lambda a: jnp.maximum(a[0], 0.0)),
        "fmac": (3, lambda a: a[0] * a[1] + a[2]),
        "load": (1, lambda a: a[0]),
        "store": (1, lambda a: a[0]),
        "copy": (1, lambda a: a[0]),
        # cmpugt/select are deliberately absent: raw (un-recomposed) graphs
        # route those groups through the per-group tensor fallback, which
        # is exactly the path the fallback tests pin down.
    }


OPCODE_KERNELS = _opcode_table()

#: opcodes whose results the functional model does NOT re-quantise
#: (moves/compares — mirrors ``emit.evaluate``)
NO_QUANT_OPCODES = frozenset({"cmpugt", "load", "store", "copy"})
