"""Pallas TPU kernel: fused row softmax with optional Taylor-series exp.

One grid step owns a block of rows; max-subtraction, exponentiation and
normalisation happen in a single VMEM residency (the paper's reduction-tree
softmax as one fused unit — §3.2 item 4 + §4.1 soft_max).  ``taylor_order``
> 0 switches exp to the paper's k-th-order Taylor expansion with 2^r range
reduction, matching the scalar-DFG functional model bit-for-bit in intent.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _taylor_exp(x, order: int, range_reduce: int):
    y = x / float(1 << range_reduce)
    acc = jnp.ones_like(y)
    term = jnp.ones_like(y)
    for k in range(1, order + 1):
        term = term * y / float(k)
        acc = acc + term
    for _ in range(range_reduce):
        acc = acc * acc
    return acc


def _softmax_kernel(x_ref, o_ref, *, taylor_order, range_reduce):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    z = x - m
    if taylor_order:
        e = _taylor_exp(z, taylor_order, range_reduce)
    else:
        e = jnp.exp(z)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "taylor_order", "range_reduce", "block_rows", "interpret"))
def fused_softmax(x: jax.Array, *, taylor_order: int = 0,
                  range_reduce: int = 2, block_rows: int = 256,
                  interpret: bool = True) -> jax.Array:
    """Softmax over the last axis of a 2-D array (rows, cols)."""
    rows, cols = x.shape
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0
    return pl.pallas_call(
        functools.partial(_softmax_kernel, taylor_order=taylor_order,
                          range_reduce=range_reduce),
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=interpret,
    )(x)
