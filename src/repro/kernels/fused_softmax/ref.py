"""Pure-jnp oracle for fused_softmax."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def taylor_exp_ref(x: jax.Array, order: int, range_reduce: int) -> jax.Array:
    y = x.astype(jnp.float32) / float(1 << range_reduce)
    acc = jnp.ones_like(y)
    term = jnp.ones_like(y)
    for k in range(1, order + 1):
        term = term * y / float(k)
        acc = acc + term
    for _ in range(range_reduce):
        acc = acc * acc
    return acc


def fused_softmax_ref(x: jax.Array, *, taylor_order: int = 0,
                      range_reduce: int = 2) -> jax.Array:
    xf = x.astype(jnp.float32)
    z = xf - jnp.max(xf, axis=-1, keepdims=True)
    e = (taylor_exp_ref(z, taylor_order, range_reduce) if taylor_order
         else jnp.exp(z))
    return (e / jnp.sum(e, axis=-1, keepdims=True)).astype(x.dtype)
