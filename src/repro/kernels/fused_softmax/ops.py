"""Public wrapper for the fused softmax kernel."""

from __future__ import annotations

import jax

from repro.kernels.fused_softmax.fused_softmax import fused_softmax
from repro.kernels.fused_softmax.ref import fused_softmax_ref


def softmax(x: jax.Array, *, taylor_order: int = 0, range_reduce: int = 2,
            use_pallas: bool = False, interpret: bool = True) -> jax.Array:
    orig_shape = x.shape
    x2 = x.reshape(-1, orig_shape[-1])
    if use_pallas:
        out = fused_softmax(x2, taylor_order=taylor_order,
                            range_reduce=range_reduce, interpret=interpret)
    else:
        out = fused_softmax_ref(x2, taylor_order=taylor_order,
                                range_reduce=range_reduce)
    return out.reshape(orig_shape)
